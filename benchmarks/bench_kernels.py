"""Kernel + serving micro-benchmarks (CPU wall time; interpret=True for
Pallas bodies — correctness-path timing, the TPU perf story lives in the
roofline analysis).

``bench_impact_scan_sweep`` is the hardware-tuning dataset for the
traced-rho impact_scan kernel: block_p x block_d x segment-skip on/off,
reporting executed grid-cell bodies (the work the TPU actually schedules
— deterministic, machine-independent) next to interpret-mode wall time.
``main --smoke`` writes the committed ``artifacts/BENCH_kernels.json``
summary (cell counts + compile counts only) and the gitignored
``artifacts/BENCH_kernels_full.json`` with per-machine timings.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
BENCH_KERNELS_JSON = os.path.join(ART, "BENCH_kernels.json")
BENCH_KERNELS_FULL_JSON = os.path.join(ART, "BENCH_kernels_full.json")

#: structured records the sweep benches append for write_kernels_json
_RECORDS: dict = {"impact_scan_sweep": [], "service": {}, "chosen": {}}


def choose_block_defaults(sweep: list[dict]) -> dict:
    """Pick ``kernel_block_p`` / ``kernel_block_d`` from the sweep.

    Deterministic criterion, machine-independent: fewest executed grid
    cells on the production variant (``rho+seg`` — mixed predicted rho
    with segment skips), tie broken toward the largest ``block_d`` then
    the largest ``block_p`` (bigger tiles amortize grid overhead at equal
    work).  Keyed by ``jax.default_backend()`` so a TPU run records its
    own row next to the CPU one instead of overwriting it."""
    rows = [r for r in sweep if r["variant"] == "rho+seg"]
    if not rows:
        return {}
    best = min(rows, key=lambda r: (r["cells"], -r["block_d"],
                                    -r["block_p"]))
    return {jax.default_backend(): dict(
        kernel_block_p=best["block_p"], kernel_block_d=best["block_d"],
        cells=best["cells"], dense_cells=best["dense_cells"])}


def _time(fn, n=3):
    fn()                                   # compile
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n


def bench_kernels() -> list[tuple]:
    r = np.random.default_rng(0)
    rows = []

    # flash attention (oracle path: the production CPU route)
    from repro.kernels.flash_attention import ops as fa
    q = jnp.asarray(r.normal(size=(4, 256, 8, 64)).astype(np.float32))
    k = jnp.asarray(r.normal(size=(4, 256, 2, 64)).astype(np.float32))
    dt = _time(lambda: fa.flash_attention(q, k, k, use_kernel=False)
               .block_until_ready())
    rows.append(("kernel/flash_attention_ref_b4s256", dt * 1e6, "oracle"))
    dt = _time(lambda: fa.flash_attention(q, k, k, block_q=128,
                                          block_kv=128)
               .block_until_ready(), n=1)
    rows.append(("kernel/flash_attention_interp_b4s256", dt * 1e6,
                 "pallas-interpret"))

    # impact scan
    from repro.kernels.impact_scan import ops as isc
    docs = jnp.asarray(r.integers(-1, 4096, (16, 2048)).astype(np.int32))
    imps = jnp.asarray((r.random((16, 2048)) * 255).astype(np.float32))
    dt = _time(lambda: isc.saat_accumulate(docs, imps, n_docs=4096,
                                           rho=1024, use_kernel=False)
               .block_until_ready())
    rows.append(("kernel/impact_scan_ref_16q", dt * 1e6, "oracle"))

    # topk
    from repro.kernels.topk import ops as tk
    s = jnp.asarray(r.normal(size=(16, 65536)).astype(np.float32))
    dt = _time(lambda: tk.topk_select(s, 64, use_kernel=False)[0]
               .block_until_ready())
    rows.append(("kernel/topk_ref_16x64k", dt * 1e6, "oracle"))

    # embedding bag
    from repro.kernels.embedding_bag import ops as eb
    t = jnp.asarray(r.normal(size=(100_000, 32)).astype(np.float32))
    ids = jnp.asarray(r.integers(-1, 100_000, (1024, 8)).astype(np.int32))
    dt = _time(lambda: eb.embedding_bag(t, ids, use_kernel=False)
               .block_until_ready())
    rows.append(("kernel/embedding_bag_ref_1k", dt * 1e6, "oracle"))

    return rows


def bench_impact_scan_sweep() -> list[tuple]:
    """Traced-rho + segment-skip block sweep on real gathered streams.

    Three variants per (block_p, block_d): ``dense`` (rho = P constant —
    what the old pre-masked kernel path paid for every query), ``rho``
    (mixed per-query predicted rho, doc grid dense) and ``rho+seg``
    (mixed rho plus per-block doc-id bounds).  The executed-cell counts
    come from the kernel's own stats output, so the "fewer grid-cell
    bodies" claim is measured, not modeled.
    """
    from benchmarks import common
    from repro.kernels.impact_scan import ops as isc
    from repro.kernels.impact_scan.kernel import posting_blocks
    from repro.retrieval import jass
    from repro.retrieval.index import block_doc_bounds

    sys_ = common.get_system()
    idx = sys_.index
    cap = min(sys_.cfg.stream_cap, 1024)   # interpret-mode budget
    qn = 8
    ds, im = jass.gather_streams(
        jnp.asarray(idx.offsets), jnp.asarray(idx.postings_doc),
        jnp.asarray(idx.postings_impact.astype(np.float32)),
        jnp.asarray(sys_.queries.terms[:qn]), cap=cap)
    nd = idx.corpus.n_docs
    p = int(ds.shape[-1])
    # the predicted-rho mix a cascade produces: mostly cheap, a few max
    rho_mix = np.asarray([0, p // 64, p // 16, p // 16, p // 4, p // 4,
                          p // 2, p][:qn], np.int32)
    rho_full = np.full(qn, p, np.int32)

    smoke = common.scale_name() == "tiny"
    bps = (128, 256)
    bds = (512, 1024) if smoke else (1024, 2048)
    rows = []
    for bp in bps:
        seg = block_doc_bounds(ds, block_p=bp, n_docs=nd)
        _, n_p = posting_blocks(p, bp)
        for bd in bds:
            n_d = -(-nd // min(bd, nd))
            dense_cells = qn * n_d * n_p
            for variant, rho, sb in (
                    ("dense", rho_full, None),
                    ("rho", rho_mix, None),
                    ("rho+seg", rho_mix, seg)):
                kw = dict(n_docs=nd, rho=jnp.asarray(rho),
                          block_p=bp, block_d=bd, seg_bounds=sb)
                _, cnt = isc.saat_accumulate(ds, im, with_stats=True,
                                             **kw)
                cells = int(np.asarray(cnt).sum())
                dt = _time(lambda kw=kw: isc.saat_accumulate(ds, im, **kw)
                           .block_until_ready(), n=1)
                rows.append((f"kernel/impact_scan/bp{bp}_bd{bd}_{variant}",
                             dt * 1e6,
                             f"cells={cells}/{dense_cells}"))
                _RECORDS["impact_scan_sweep"].append(dict(
                    block_p=bp, block_d=bd, variant=variant,
                    cells=cells, dense_cells=dense_cells,
                    us=round(dt * 1e6, 1)))
    _RECORDS["chosen"] = choose_block_defaults(
        _RECORDS["impact_scan_sweep"])
    for plat, c in _RECORDS["chosen"].items():
        rows.append((f"kernel/impact_scan/chosen_{plat}", float(c["cells"]),
                     f"block_p={c['kernel_block_p']} "
                     f"block_d={c['kernel_block_d']}"))
    return rows


def bench_kernel_service_compiles() -> list[tuple]:
    """Acceptance probe: n_compiles stays O(1) under mixed per-query rho
    through the service with the kernel path forced (interpret mode)."""
    from repro.core import experiment as E
    from repro.serving import pipeline as sp
    from repro.serving.admission import AdmissionConfig
    from repro.serving.service import EngineBackend, RetrievalService

    sys_ = E.build_system(E.ExperimentConfig(
        n_docs=2_000, vocab=5_000, n_queries=64, stream_cap=256,
        pool_depth=400, gold_depth=100, query_batch=32, seed=11))
    cuts = sys_.rho_cutoffs
    cfg = sp.ServingConfig(knob="rho", cutoffs=cuts, rerank_depth=50,
                           stream_cap=sys_.cfg.stream_cap,
                           use_kernel=True, kernel_block_p=64,
                           kernel_block_d=512)
    server = sp.RetrievalServer(sys_.index, None, cfg)
    n_cls = len(cuts) + 1
    mix = {"m": 1}
    server.predict_classes = (
        lambda qt: (np.arange(qt.shape[0]) * mix["m"]) % n_cls)
    service = RetrievalService(
        EngineBackend(server, query_len=sys_.queries.terms.shape[1]),
        AdmissionConfig(max_batch=32, pad_multiple=cfg.pad_multiple))
    service.serve_all(list(sys_.queries.terms[:32]))      # warm
    base = server.engine.n_compiles
    for m in (1, 3, 5, 7):                # rotate the per-query rho mix
        mix["m"] = m
        service.serve_all(list(sys_.queries.terms[:32]))
    const = server.engine.n_compiles == base
    _RECORDS["service"] = dict(n_compiles=int(server.engine.n_compiles),
                               o1_under_mixed_rho=bool(const))
    if not const:       # self-enforcing: run.py counts raised benches
        raise RuntimeError(
            f"kernel path recompiled under mixed per-query rho "
            f"({base} -> {server.engine.n_compiles} executables)")
    return [("kernel/service_mixed_rho_compiles",
             server.engine.n_compiles, "O(1) PASS")]


def write_kernels_json(path: str | None = None,
                       full_path: str | None = None,
                       rows: list[tuple] | None = None) -> str:
    """Committed summary (deterministic cell/compile counts only) +
    gitignored full record (per-machine timings).

    The committed summary is defined at the CI smoke scale; at any other
    scale the default path writes only the gitignored full record, so a
    default-scale ``run.py`` never dirties the tracked tiny-scale file
    the bench-smoke job diff-checks.  An explicitly requested ``path``
    is always honored."""
    from benchmarks import common
    explicit = path is not None
    path = path or BENCH_KERNELS_JSON
    full_path = full_path or BENCH_KERNELS_FULL_JSON
    sweep = _RECORDS["impact_scan_sweep"]
    skipped = [r for r in sweep if r["variant"] == "rho+seg"]
    summary = {
        "scale": common.scale_name(),
        "impact_scan_sweep": [
            {k: r[k] for k in ("block_p", "block_d", "variant", "cells",
                               "dense_cells")} for r in sweep],
        "min_cell_fraction": (
            min(r["cells"] / r["dense_cells"] for r in skipped)
            if skipped else None),
        "chosen_defaults": _RECORDS["chosen"] or None,
        "service_mixed_rho": _RECORDS["service"] or None,
    }
    if _RECORDS["chosen"] and os.path.exists(path):
        try:                        # keep other platforms' chosen rows
            with open(path) as f:
                prev = json.load(f).get("chosen_defaults") or {}
            summary["chosen_defaults"] = {**prev, **_RECORDS["chosen"]}
        except (OSError, ValueError):
            pass
    os.makedirs(ART, exist_ok=True)
    wrote = None
    if explicit or common.scale_name() == "tiny":
        with open(path, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
            f.write("\n")
        wrote = path
    full = dict(summary, unix_time=time.time(),
                sweep_us={f"bp{r['block_p']}_bd{r['block_d']}_"
                          f"{r['variant']}": r["us"] for r in sweep},
                rows=[[n, float(v), str(d)] for n, v, d in (rows or [])])
    with open(full_path, "w") as f:
        json.dump(full, f, indent=2, sort_keys=True)
    return os.path.abspath(wrote or full_path)


def bench_cascade_latency() -> list[tuple]:
    """The prediction overhead the paper argues is negligible."""
    from benchmarks import common
    from repro.core import cascade as cl
    from repro.core import experiment as E
    from repro.core import labeling

    sys_ = common.get_system()
    m = common.get_med("k")["rbp"]
    labels = np.asarray(labeling.envelope_labels(m, 0.05))
    casc = cl.train_cascade(sys_.features, labels,
                            n_cutoffs=len(sys_.k_cutoffs),
                            forest_kwargs=common.forest_kwargs())
    x = jnp.asarray(sys_.features[:512])
    fn = jax.jit(lambda xx: cl.predict_batched(casc, xx, 0.75))
    fn(x).block_until_ready()
    t0 = time.time()
    for _ in range(10):
        fn(x).block_until_ready()
    dt = (time.time() - t0) / 10
    return [("serving/cascade_predict_512q", dt * 1e6,
             f"{512 / dt:.0f} q/s")]


def bench_serving() -> list[tuple]:
    """End-to-end pipeline: dynamic vs fixed mean width + throughput."""
    from benchmarks import common
    from repro.core import cascade as cl
    from repro.core import labeling
    from repro.serving import pipeline as sp

    sys_ = common.get_system()
    m = common.get_med("k")["rbp"]
    labels = np.asarray(labeling.envelope_labels(m, 0.05))
    casc = cl.train_cascade(sys_.features, labels,
                            n_cutoffs=len(sys_.k_cutoffs),
                            forest_kwargs=common.forest_kwargs())
    cfg = sp.ServingConfig(knob="k", cutoffs=sys_.k_cutoffs,
                           threshold=0.75, rerank_depth=100,
                           stream_cap=sys_.cfg.stream_cap)
    server = sp.RetrievalServer(sys_.index, casc, cfg)
    qt = sys_.queries.terms[:256]
    out = server.serve_batch(qt)          # includes compile
    t0 = time.time()
    out = server.serve_batch(qt)
    dyn_s = time.time() - t0
    t0 = time.time()
    fixed = server.serve_fixed(qt, sys_.k_cutoffs[-1])
    fix_s = time.time() - t0
    return [
        ("serving/dynamic_256q", dyn_s / 256 * 1e6,
         f"mean_k={out['mean_param']:.0f}"),
        ("serving/fixed_max_256q", fix_s / 256 * 1e6,
         f"mean_k={fixed['mean_param']:.0f}"),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale, interpret mode (CI)")
    ap.add_argument("--out", default=None,
                    help=f"summary JSON path (default {BENCH_KERNELS_JSON})")
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ["REPRO_BENCH_SCALE"] = "tiny"
    print("name,us_per_call,derived")
    rows: list[tuple] = []
    for b in (bench_impact_scan_sweep, bench_kernel_service_compiles):
        for row in b():
            rows.append(row)
            name, v, derived = row
            print(f"{name},{v:.1f},{derived}", flush=True)
    path = write_kernels_json(args.out, rows=rows)
    print(f"wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main()
