"""Kernel + serving micro-benchmarks (CPU wall time; interpret=True for
Pallas bodies — correctness-path timing, the TPU perf story lives in the
roofline analysis)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, n=3):
    fn()                                   # compile
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n


def bench_kernels() -> list[tuple]:
    r = np.random.default_rng(0)
    rows = []

    # flash attention (oracle path: the production CPU route)
    from repro.kernels.flash_attention import ops as fa
    q = jnp.asarray(r.normal(size=(4, 256, 8, 64)).astype(np.float32))
    k = jnp.asarray(r.normal(size=(4, 256, 2, 64)).astype(np.float32))
    dt = _time(lambda: fa.flash_attention(q, k, k, use_kernel=False)
               .block_until_ready())
    rows.append(("kernel/flash_attention_ref_b4s256", dt * 1e6, "oracle"))
    dt = _time(lambda: fa.flash_attention(q, k, k, block_q=128,
                                          block_kv=128)
               .block_until_ready(), n=1)
    rows.append(("kernel/flash_attention_interp_b4s256", dt * 1e6,
                 "pallas-interpret"))

    # impact scan
    from repro.kernels.impact_scan import ops as isc
    docs = jnp.asarray(r.integers(-1, 4096, (16, 2048)).astype(np.int32))
    imps = jnp.asarray((r.random((16, 2048)) * 255).astype(np.float32))
    dt = _time(lambda: isc.saat_accumulate(docs, imps, n_docs=4096,
                                           rho=1024, use_kernel=False)
               .block_until_ready())
    rows.append(("kernel/impact_scan_ref_16q", dt * 1e6, "oracle"))

    # topk
    from repro.kernels.topk import ops as tk
    s = jnp.asarray(r.normal(size=(16, 65536)).astype(np.float32))
    dt = _time(lambda: tk.topk_select(s, 64, use_kernel=False)[0]
               .block_until_ready())
    rows.append(("kernel/topk_ref_16x64k", dt * 1e6, "oracle"))

    # embedding bag
    from repro.kernels.embedding_bag import ops as eb
    t = jnp.asarray(r.normal(size=(100_000, 32)).astype(np.float32))
    ids = jnp.asarray(r.integers(-1, 100_000, (1024, 8)).astype(np.int32))
    dt = _time(lambda: eb.embedding_bag(t, ids, use_kernel=False)
               .block_until_ready())
    rows.append(("kernel/embedding_bag_ref_1k", dt * 1e6, "oracle"))

    return rows


def bench_cascade_latency() -> list[tuple]:
    """The prediction overhead the paper argues is negligible."""
    from benchmarks import common
    from repro.core import cascade as cl
    from repro.core import experiment as E
    from repro.core import labeling

    sys_ = common.get_system()
    m = common.get_med("k")["rbp"]
    labels = np.asarray(labeling.envelope_labels(m, 0.05))
    casc = cl.train_cascade(sys_.features, labels,
                            n_cutoffs=len(sys_.k_cutoffs),
                            forest_kwargs=common.forest_kwargs())
    x = jnp.asarray(sys_.features[:512])
    fn = jax.jit(lambda xx: cl.predict_batched(casc, xx, 0.75))
    fn(x).block_until_ready()
    t0 = time.time()
    for _ in range(10):
        fn(x).block_until_ready()
    dt = (time.time() - t0) / 10
    return [("serving/cascade_predict_512q", dt * 1e6,
             f"{512 / dt:.0f} q/s")]


def bench_serving() -> list[tuple]:
    """End-to-end pipeline: dynamic vs fixed mean width + throughput."""
    from benchmarks import common
    from repro.core import cascade as cl
    from repro.core import labeling
    from repro.serving import pipeline as sp

    sys_ = common.get_system()
    m = common.get_med("k")["rbp"]
    labels = np.asarray(labeling.envelope_labels(m, 0.05))
    casc = cl.train_cascade(sys_.features, labels,
                            n_cutoffs=len(sys_.k_cutoffs),
                            forest_kwargs=common.forest_kwargs())
    cfg = sp.ServingConfig(knob="k", cutoffs=sys_.k_cutoffs,
                           threshold=0.75, rerank_depth=100,
                           stream_cap=sys_.cfg.stream_cap)
    server = sp.RetrievalServer(sys_.index, casc, cfg)
    qt = sys_.queries.terms[:256]
    out = server.serve_batch(qt)          # includes compile
    t0 = time.time()
    out = server.serve_batch(qt)
    dyn_s = time.time() - t0
    t0 = time.time()
    fixed = server.serve_fixed(qt, sys_.k_cutoffs[-1])
    fix_s = time.time() - t0
    return [
        ("serving/dynamic_256q", dyn_s / 256 * 1e6,
         f"mean_k={out['mean_param']:.0f}"),
        ("serving/fixed_max_256q", fix_s / 256 * 1e6,
         f"mean_k={fixed['mean_param']:.0f}"),
    ]
