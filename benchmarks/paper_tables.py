"""Benchmarks reproducing the paper's tables and figures.

Each function returns CSV rows (name, us_per_call, derived) where
``derived`` carries the headline reproduction number and ``us_per_call``
the wall time of the underlying per-query computation.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import experiment as E
from repro.core import labeling, med, tradeoff

ROWS = list


def _us(total_s: float, n: int) -> float:
    return 1e6 * total_s / max(n, 1)


def bench_table3() -> list[tuple]:
    """Table 3: MED_RBP at the 9 k cutoffs for the first topics."""
    sys_ = common.get_system()
    m = common.get_med("k")["rbp"]
    rows = []
    for qi in range(4):
        vals = "|".join(f"{v:.3f}" for v in m[qi])
        rows.append((f"table3/topic{qi}", _us(common.med_seconds("k"),
                                              sys_.queries.n_queries), vals))
    # monotonicity rate across the whole collection (should be ~1.0)
    mono = float(((m[:, 1:] - m[:, :-1]) <= 1e-5).mean())
    rows.append(("table3/monotone_frac", 0.0, f"{mono:.4f}"))
    return rows


def _method_table(knob: str, metric: str, tau: float, tag: str,
                  thresholds=(0.75, 0.80, 0.85)) -> list[tuple]:
    sys_ = common.get_system()
    m = common.get_med(knob)[metric]
    cutoffs = sys_.k_cutoffs if knob == "k" else sys_.rho_cutoffs
    t0 = time.time()
    res = E.run_methods(sys_, m, cutoffs, tau=tau, thresholds=thresholds,
                        n_folds=3, forest_kwargs=common.forest_kwargs())
    train_s = time.time() - t0
    rows = []
    for r in res.table:
        rows.append((
            f"{tag}/{r['method']}",
            _us(train_s, sys_.queries.n_queries),
            f"pred_{knob}={r['pred_k']:.0f};fixed_{knob}={r['fixed_k']:.0f};"
            f"gain={r['k_gain_pct']:+.0f}%;pred_med={r['pred_med']:.3f};"
            f"med_gain={r['med_gain_pct']:+.0f}%",
        ))
    return rows


def bench_table4() -> list[tuple]:
    """Table 4: interpolated k at MED_RBP <= 0.05."""
    return _method_table("k", "rbp", 0.05, "table4")


def bench_table5() -> list[tuple]:
    """Table 5: interpolated k at MED_ERR <= 0.05."""
    return _method_table("k", "err", 0.05, "table5")


def bench_table6() -> list[tuple]:
    """Table 6: interpolated rho at MED_RBP <= 0.05."""
    return _method_table("rho", "rbp", 0.05, "table6")


def bench_fig6() -> list[tuple]:
    """Figure 6: fixed-cutoff horizon + cascade points, tau in {.05,.10}."""
    sys_ = common.get_system()
    m = common.get_med("k")["rbp"]
    rows = []
    hor = tradeoff.horizon(m, sys_.k_cutoffs)
    for p in hor:
        rows.append((f"fig6/horizon_k{int(p.mean_cutoff)}", 0.0,
                     f"med={p.mean_med:.4f}"))
    for tau in (0.05, 0.10):
        res = E.run_methods(sys_, m, sys_.k_cutoffs, tau=tau,
                            thresholds=(0.75,), n_folds=3,
                            kinds=("cascade",),
                            forest_kwargs=common.forest_kwargs())
        r = [x for x in res.table if x["method"] == "cascade_t0.75"][0]
        rows.append((f"fig6/cascade_tau{tau}", 0.0,
                     f"k={r['pred_k']:.0f};med={r['pred_med']:.4f};"
                     f"gain={r['k_gain_pct']:+.0f}%"))
    return rows


def bench_fig8() -> list[tuple]:
    """Figure 8: % of queries inside the envelope vs mean k."""
    sys_ = common.get_system()
    m = common.get_med("k")["rbp"]
    tau = 0.10
    res = E.run_methods(sys_, m, sys_.k_cutoffs, tau=tau,
                        thresholds=(0.75,), n_folds=3, kinds=("cascade",),
                        forest_kwargs=common.forest_kwargs())
    rows = []
    labels = res.labels
    rows.append(("fig8/oracle", 0.0,
                 f"mean_k={tradeoff.mean_cutoff_value(labels, np.array(sys_.k_cutoffs)):.0f};"
                 f"pct_under={tradeoff.pct_under_target(m, labels, tau):.3f}"))
    pred = res.preds["cascade_t0.75"]
    rows.append(("fig8/cascade", 0.0,
                 f"mean_k={tradeoff.mean_cutoff_value(pred, np.array(sys_.k_cutoffs)):.0f};"
                 f"pct_under={tradeoff.pct_under_target(m, pred, tau):.3f}"))
    for ci, k in enumerate(sys_.k_cutoffs):
        pctf = float((m[:, ci] <= tau).mean())
        rows.append((f"fig8/fixed_k{k}", 0.0, f"pct_under={pctf:.3f}"))
    return rows


def bench_table7() -> list[tuple]:
    """Table 7: held-out validation with (synthetic) relevance judgments.

    Judgments are planted from the second-stage gold scores (pool-to-depth
    style), mirroring how the paper validates that low MED_RBP implies no
    measurable NDCG@10/ERR loss on held-out queries.
    """
    sys_ = common.get_system()
    m = common.get_med("k")["rbp"]
    cutoffs = sys_.k_cutoffs
    qn = sys_.queries.n_queries
    held = np.arange(qn - 50, qn)         # 50 held-out topics
    res = E.run_methods(sys_, m, cutoffs, tau=0.05, thresholds=(0.75,),
                        n_folds=3, kinds=("cascade",),
                        forest_kwargs=common.forest_kwargs())
    pred = res.preds["cascade_t0.75"]

    # judge pool: binary relevance for top-12 gold docs per held query
    import jax.numpy as jnp
    from repro.retrieval import gold, jass
    idx = sys_.index
    offsets = jnp.asarray(idx.offsets)
    pdoc = jnp.asarray(idx.postings_doc)
    pimp = jnp.asarray(idx.postings_impact.astype(np.float32))
    pscore = jnp.asarray(idx.postings_score)
    qt = jnp.asarray(sys_.queries.terms[held])
    ds, im = jass.gather_streams(offsets, pdoc, pimp, qt,
                                 cap=sys_.cfg.stream_cap)
    acc = jass.saat_scores(ds, im, sys_.cfg.n_docs, ds.shape[-1])
    deep = jass.rank_from_scores(acc, sys_.cfg.pool_depth)
    sdocs, s3 = jass.gather_score_streams(offsets, pdoc, pscore, qt,
                                          cap=sys_.cfg.stream_cap)
    a1, a2, a3 = jass.scorer_accumulators(sdocs, s3, sys_.cfg.n_docs)
    stage2 = gold.second_stage_scores(a1, a2, a3,
                                      jnp.asarray(idx.corpus.doc_len),
                                      jnp.asarray(held))
    gold_rank = np.asarray(gold.gold_run_k(stage2, deep, 12))

    def ndcg10_err(run):
        nd, er = [], []
        for qi in range(len(held)):
            rel = {int(d): 1 for d in gold_rank[qi] if d >= 0}
            dcg = sum(rel.get(int(d), 0) / np.log2(i + 2)
                      for i, d in enumerate(run[qi][:10]))
            ideal = sum(1 / np.log2(i + 2) for i in range(min(10, len(rel))))
            nd.append(dcg / max(ideal, 1e-9))
            e, notfound = 0.0, 1.0
            for i, d in enumerate(run[qi][:10]):
                r = 0.5 * rel.get(int(d), 0)
                e += notfound * r / (i + 1)
                notfound *= (1 - r)
            er.append(e)
        return float(np.mean(nd)), float(np.mean(er))

    rows = []
    for name, classes in (("oracle", res.labels[held]),
                          ("cascade_t0.75", pred[held]),
                          ("fixed_max", np.full(len(held),
                                                len(cutoffs) - 1))):
        ks = np.array(cutoffs)[np.minimum(classes, len(cutoffs) - 1)]
        runs = np.stack([
            np.asarray(gold.candidate_run_k(
                stage2[qi:qi + 1], deep[qi:qi + 1], int(ks[qi]), 10))[0]
            for qi in range(len(held))])
        nd, er = ndcg10_err(runs)
        rows.append((f"table7/{name}", 0.0,
                     f"ndcg10={nd:.3f};err={er:.3f};mean_k={ks.mean():.0f}"))
    return rows


def bench_variable_thresholds() -> list[tuple]:
    """Paper §5 roadmap: per-node tuned thresholds vs scalar t."""
    import jax.numpy as jnp

    from repro.core import cascade as cascade_lib
    from repro.core import labeling

    sys_ = common.get_system()
    m = common.get_med("k")["rbp"]
    labels = np.asarray(labeling.envelope_labels(m, 0.05))
    n = len(labels)
    tr, va, te = slice(0, n // 2), slice(n // 2, 3 * n // 4), \
        slice(3 * n // 4, n)
    casc = cascade_lib.train_cascade(
        sys_.features[tr], labels[tr], n_cutoffs=len(sys_.k_cutoffs),
        forest_kwargs=common.forest_kwargs())
    tv = cascade_lib.tune_thresholds(casc, sys_.features[va], m[va],
                                     sys_.k_cutoffs, tau=0.05)
    rows = []
    for name, t_ in (("scalar_t0.75", 0.75), ("tuned_vector", tv)):
        pred = np.asarray(cascade_lib.predict_batched(
            casc, jnp.asarray(sys_.features[te]), t_))
        mk = tradeoff.mean_cutoff_value(pred, np.array(sys_.k_cutoffs))
        pct = tradeoff.pct_under_target(m[te], pred, 0.05)
        rows.append((f"var_thresh/{name}", 0.0,
                     f"mean_k={mk:.0f};pct_under={pct:.3f}"))
    return rows


def bench_med_throughput() -> list[tuple]:
    """MED computation speed (the labeling pipeline's inner loop)."""
    rng = np.random.default_rng(0)
    a = rng.integers(0, 100_000, (256, 400)).astype(np.int32)
    b = rng.integers(0, 100_000, (256, 400)).astype(np.int32)
    import jax
    import jax.numpy as jnp
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    med.med_rbp(aj, bj).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        med.med_rbp(aj, bj).block_until_ready()
    dt = (time.time() - t0) / 5
    return [("med_rbp/256q_depth400", _us(dt, 256), f"{256 / dt:.0f} q/s")]
