"""§Roofline: build the per-(arch x shape) table from dry-run artifacts.

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun), prints the
three roofline terms, the dominant bottleneck, the 6ND model-FLOPs ratio,
and a one-line lever per cell.  Also emits EXPERIMENTS-ready markdown via
--markdown.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

LEVERS = {
    "compute_s": "raise arithmetic intensity (fuse, bf16 everywhere, "
                 "cut remat recompute)",
    "memory_s": "cut bytes: fuse elementwise chains, keep activations "
                "bf16, larger blocks to amortize reloads",
    "collective_s": "reshard: fewer all-gathers (FSDP prefetch), overlap "
                    "collectives with compute, 2x pod-axis DP only",
}


def load(mesh: str = "single") -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, f"*__{mesh}.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def fmt_row(r: dict) -> dict:
    out = {"arch": r["arch"], "shape": r["shape"], "status": r["status"]}
    if r["status"] != "ok":
        out["note"] = r.get("reason", r.get("error", ""))[:80]
        return out
    rf = r["roofline"]
    if "compute_s" not in rf:
        out["note"] = rf.get("note", "")
        return out
    out.update({
        "compute_s": f"{rf['compute_s']:.3g}",
        "memory_s": f"{rf['memory_s']:.3g}",
        "collective_s": f"{rf['collective_s']:.3g}",
        "dominant": rf["dominant"].replace("_s", ""),
        "useful_flops": f"{rf.get('useful_flops_frac', float('nan')):.2f}",
        "roofline_frac": f"{rf.get('roofline_fraction', float('nan')):.4f}",
        "fits_hbm": r["memory"]["fits_hbm"],
        "temp_GiB": f"{r['memory']['temp_bytes'] / 2**30:.1f}",
        "lever": LEVERS[rf["dominant"]],
    })
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = [fmt_row(r) for r in load(args.mesh)]
    if args.markdown:
        cols = ["arch", "shape", "compute_s", "memory_s", "collective_s",
                "dominant", "useful_flops", "roofline_frac", "temp_GiB",
                "fits_hbm"]
        print("| " + " | ".join(cols) + " |")
        print("|" + "---|" * len(cols))
        for r in rows:
            print("| " + " | ".join(str(r.get(c, "—")) for c in cols) + " |")
    else:
        for r in rows:
            print(json.dumps(r))


def bench_roofline() -> list[tuple]:
    """run.py hook: emit one CSV row per completed single-pod cell."""
    rows = []
    for r in load("single"):
        if r["status"] == "ok" and "compute_s" in r.get("roofline", {}):
            rf = r["roofline"]
            bound = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
            rows.append((f"roofline/{r['arch']}/{r['shape']}",
                         bound * 1e6,
                         f"dom={rf['dominant']};frac="
                         f"{rf.get('roofline_fraction', 0):.4f}"))
        else:
            rows.append((f"roofline/{r['arch']}/{r['shape']}", 0.0,
                         r["status"]))
    return rows


if __name__ == "__main__":
    main()
