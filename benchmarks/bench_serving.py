"""Serving benchmarks: dynamic vs fixed wall-clock under single dispatch,
plus the async admission path (RetrievalService) end to end.

The honest comparison the paper's efficiency claim needs: the dynamic
path (cascade prediction + traced per-query parameter) must not cost more
wall-clock than serving everyone at the fixed maximum parameter.  With
the single-dispatch engine both paths share the same executables, so the
dynamic overhead is exactly the cascade forward pass — reported here as
per-stage timings plus the executable-cache size (compile count).

The continuous-batching race (``bench_continuous_scheduler``) runs the
same query stream through the slot-table scheduler twice — per-query
predicted ρ vs everyone at the fixed maximum — and counts the chunk
dispatches each arm executes.  Early retirement makes the dynamic arm's
count scale with the *predicted* work, which is where dynamic beats
fixed on wall clock instead of merely tying it.

Machine-readable output follows the BENCH_kernels/BENCH_online split:
``artifacts/BENCH_serving.json`` is the small *committed* summary —
deterministic dispatch/retirement counts and acceptance booleans,
written at the CI smoke scale and diff-checked by bench-smoke — while
the gitignored ``artifacts/BENCH_serving_full.json`` carries the
per-machine timings (p50/p99, queue-vs-service breakdown, per-stage ms,
throughput).  ``--smoke`` runs the tiny scale for CI.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
BENCH_JSON = os.path.join(ART, "BENCH_serving.json")
FULL_JSON = os.path.join(ART, "BENCH_serving_full.json")

#: filled by bench_continuous_scheduler / bench_paced_deadlines; the
#: committed summary is assembled from these (deterministic fields only)
_RECORDS: dict = {"scheduler": None, "deadline": None, "sharded": None,
                  "knobs": None, "obs": None}


def _build_server():
    from benchmarks import common
    from repro.core import cascade as cl
    from repro.core import labeling
    from repro.serving import pipeline as sp

    sys_ = common.get_system()
    m = common.get_med("k")["rbp"]
    labels = np.asarray(labeling.envelope_labels(m, 0.05))
    casc = cl.train_cascade(sys_.features, labels,
                            n_cutoffs=len(sys_.k_cutoffs),
                            forest_kwargs=common.forest_kwargs())
    cfg = sp.ServingConfig(knob="k", cutoffs=sys_.k_cutoffs,
                           threshold=0.75, rerank_depth=100,
                           stream_cap=sys_.cfg.stream_cap)
    return sys_, sp.RetrievalServer(sys_.index, casc, cfg)


def bench_dynamic_vs_fixed() -> list[tuple]:
    """Acceptance row: dynamic wall-clock at or below fixed max-param."""
    sys_, server = _build_server()
    qt = sys_.queries.terms[:256]
    qlen = qt.shape[1]
    server.engine.warmup([256], qlen)     # compile off the timed path

    def best_of(fn, n=3):
        ts = []
        for _ in range(n):
            t0 = time.time()
            fn()
            ts.append(time.time() - t0)
        return min(ts)

    server.serve_batch(qt)                # cascade jit warmup
    dyn_s = best_of(lambda: server.serve_batch(qt))
    fix_s = best_of(lambda: server.serve_fixed(qt, sys_.k_cutoffs[-1]))
    out = server.serve_batch(qt)
    rows = [
        ("serving/dynamic_single_dispatch_256q", dyn_s / 256 * 1e6,
         f"mean_k={out['mean_param']:.0f}"),
        ("serving/fixed_max_single_dispatch_256q", fix_s / 256 * 1e6,
         f"mean_k={sys_.k_cutoffs[-1]}"),
        ("serving/dynamic_vs_fixed_ratio", dyn_s / fix_s,
         "PASS" if dyn_s <= fix_s * 1.05 else "FAIL"),
        ("serving/executable_cache", server.engine.n_compiles,
         "compiles (constant in class diversity)"),
    ]
    for key, ms in out["timings"].items():
        stage = key.removesuffix("_ms")
        rows.append((f"serving/stage_{stage}_us", ms * 1e3,
                     "per 256q batch"))
    return rows


def bench_compile_amortization() -> list[tuple]:
    """Per-bucket reference vs single dispatch on a many-bucket batch."""
    sys_, server = _build_server()
    qt = sys_.queries.terms[:128]
    server.serve_batch(qt)                # warm both paths
    server.serve_batch_reference(qt)
    t0 = time.time()
    server.serve_batch(qt)
    dyn_s = time.time() - t0
    t0 = time.time()
    out_ref = server.serve_batch_reference(qt)
    ref_s = time.time() - t0
    n_buckets = len(set(out_ref["classes"].tolist()))
    return [
        ("serving/single_dispatch_128q", dyn_s / 128 * 1e6,
         f"{n_buckets}_live_buckets"),
        ("serving/per_bucket_reference_128q", ref_s / 128 * 1e6,
         f"{n_buckets}_live_buckets"),
    ]


def bench_admission_service() -> list[tuple]:
    """The unified async path: deadline-driven admission end to end.

    Feeds a query stream through RetrievalService (threaded: prediction
    for batch N+1 overlapping dispatch of batch N) and reports request
    latency percentiles with the queue-vs-service breakdown the
    deployment loop tunes deadlines against.
    """
    from repro.serving.admission import AdmissionConfig
    from repro.serving.service import EngineBackend, RetrievalService

    sys_, server = _build_server()
    n_stream = min(512, sys_.queries.n_queries)
    qt = sys_.queries.terms[:n_stream]
    backend = EngineBackend(server, query_len=qt.shape[1])
    service = RetrievalService(backend, AdmissionConfig(
        max_batch=128, pad_multiple=server.cfg.pad_multiple,
        max_wait_ms=2.0, default_deadline_ms=100.0))
    service.warmup_now([128])             # deploy-time shape
    with service:
        service.serve_all(list(qt[:128]))     # cascade jit warmup
        t0 = time.time()
        results = service.serve_all(list(qt))
        wall_s = time.time() - t0
    # total_ms spans submit -> resolve (incl. the predict/execute handoff
    # wait), the same clock deadline_met is judged against
    lat = [r["total_ms"] for r in results]
    met = np.mean([r["deadline_met"] for r in results])
    return [
        ("serving/admission_request_p50_ms", float(np.percentile(lat, 50)),
         f"{n_stream}q_stream"),
        ("serving/admission_request_p99_ms", float(np.percentile(lat, 99)),
         f"deadline_met={met:.0%}"),
        ("serving/admission_queue_p50_ms",
         float(np.percentile([r["queue_ms"] for r in results], 50)),
         "admission delay"),
        ("serving/admission_service_p50_ms",
         float(np.percentile([r["service_ms"] for r in results], 50)),
         "backend execute"),
        ("serving/admission_throughput_qps", n_stream / wall_s,
         f"shapes={sorted(service.queue.shape_counts)}"),
        ("serving/admission_warmed_shapes", len(service.warmup.compiled),
         "learned warmup policy"),
    ]


def _hash_rows(qt):
    qt = np.asarray(qt)
    return np.where(qt >= 0, qt, 0).sum(axis=1) + (qt >= 0).sum(axis=1)


def _build_knob_server(primary: str, *, with_depth: bool = False):
    """A continuous-race server on the chosen primary knob (rho = the
    anytime-work knob the scheduler retires against, k = the pool-width
    knob), classes *stubbed* as content hashes; ``with_depth`` also
    registers the depth knob, stubbed from a decorrelated hash.

    The stubs are deliberate: the committed summary carries dispatch and
    stage-2 row counts, and integer-hash classes make them
    platform-exact, where a trained forest's float thresholds could flip
    a borderline query between classes across BLAS builds and dirty the
    diff-checked file.  The cascade's forward cost is measured by
    bench_dynamic_vs_fixed; these benches isolate what early retirement
    and prefix-masked reranking save."""
    from benchmarks import common
    from repro.core import knobs as knobs_lib
    from repro.serving import pipeline as sp

    sys_ = common.get_system()
    cuts = sys_.rho_cutoffs if primary == "rho" else sys_.k_cutoffs
    dgrid = None
    if with_depth:
        pool = 100 if primary == "rho" else int(max(cuts))
        dgrid = knobs_lib.depth_cutoffs(pool)
    cfg = sp.ServingConfig(knob=primary, cutoffs=cuts, rerank_depth=100,
                           stream_cap=sys_.cfg.stream_cap,
                           depth_cutoffs=dgrid)
    server = sp.RetrievalServer(sys_.index, None, cfg)
    n_cls = len(cuts) + 1
    real = server.predict_classes

    def classes_of(qt, knob=None):
        if knob not in (None, primary):    # depth etc.: real registry
            return real(qt, knob=knob)
        return (_hash_rows(qt) % n_cls).astype(np.int64)

    server.predict_classes = classes_of
    if with_depth:
        n_dcls = len(dgrid) + 1

        def pdepth(qt):
            # decorrelated from the primary hash so mixed primary/depth
            # buckets genuinely co-occur in one slot table
            cls = ((_hash_rows(qt) // 3) % n_dcls).astype(np.int64)
            return cls, server.params_of(cls, knob="depth")

        server.predict_depths = pdepth
    return sys_, server


def _build_rho_server():
    return _build_knob_server("rho")


def _continuous_run(server, qt, *, fixed_param=None, slots=8, grain=8):
    # a small table on purpose: the chunk program spans the whole slot
    # table, so the dispatch count (the wall-clock driver on the oracle
    # path, where masked rows still cost) only tracks the per-query
    # window savings when the table drains often enough to refill —
    # at slots=grain the race measures retirement, not idle capacity
    from repro.serving.service import ContinuousBackend, RetrievalService

    backend = ContinuousBackend(server, query_len=qt.shape[1],
                                slots=slots, grain=grain,
                                fixed_param=fixed_param)
    svc = RetrievalService(backend)
    backend.scheduler.warmup()            # compile off the timed path
    t0 = time.perf_counter()
    results = svc.serve_all(list(qt), deadline_ms=1e9)
    wall_s = time.perf_counter() - t0
    return backend, results, wall_s


def bench_continuous_scheduler() -> list[tuple]:
    """The dynamic-vs-fixed race, continuous-batching edition.

    Same slot table, same four executables, same stream: the dynamic arm
    retires each query once its predicted ρ is exhausted, the fixed arm
    runs everyone to the maximum.  Reports chunk-dispatch counts (the
    deterministic mechanism) and the wall-clock ratio (the observable
    win), plus bit-identity against the batch-once engine and compile
    flatness across ragged churn."""
    sys_, server = _build_rho_server()
    n = min(192, sys_.queries.n_queries)
    qt = sys_.queries.terms[:n]
    cap = int(sys_.cfg.stream_cap)

    dyn_b, dyn_out, dyn_s = _continuous_run(server, qt)
    fix_b, fix_out, fix_s = _continuous_run(server, qt, fixed_param=cap)

    # bit-identity of the dynamic arm vs one batch-once serve
    classes = np.asarray(server.predict_classes(qt))
    ranked_ref, _ = server.engine.serve(qt, server.params_of(classes))
    bit_identical = all(
        np.array_equal(res["ranked"], ranked_ref[i])
        for i, res in enumerate(dyn_out))

    # compile flatness across ragged admit/retire churn: a fresh service
    # over the same (already warmed) engine must add zero executables
    from repro.serving.service import ContinuousBackend, RetrievalService
    svc = RetrievalService(ContinuousBackend(
        server, query_len=qt.shape[1], slots=8, grain=8))
    n0 = server.engine.n_compiles
    for size in (1, 5, 8, 3, 7, 2, 6, 4):
        svc.serve_all(list(qt[:size]), deadline_ms=1e9)
    churn_compiles = server.engine.n_compiles - n0

    dyn_windows = sum(res["chunks_executed"] for res in dyn_out)
    fix_windows = sum(res["chunks_executed"] for res in fix_out)
    dyn_st = dyn_b.scheduler.stats()
    fix_st = fix_b.scheduler.stats()
    ratio = dyn_windows / fix_windows
    _RECORDS["scheduler"] = {
        "knob": "rho",
        "n_queries": int(n),
        "slots": dyn_st["slots"],
        "grain": dyn_st["grain"],
        "chunk_p": dyn_st["chunk_p"],
        "chunks_max": dyn_st["chunks_max"],
        "dynamic_chunk_windows": int(dyn_windows),
        "fixed_chunk_windows": int(fix_windows),
        "dynamic_vs_fixed_ratio": round(ratio, 4),
        "dynamic_chunk_dispatches": dyn_st["n_chunk_calls"],
        "fixed_chunk_dispatches": fix_st["n_chunk_calls"],
        "retire_reasons": dyn_st["retire_reasons"],
        "dynamic_wins_wall_clock": bool(dyn_s < fix_s),
        "bit_identical_to_batch_once": bool(bit_identical),
        "zero_compiles_under_churn": bool(churn_compiles == 0),
    }
    return [
        ("serving/continuous_dynamic_qps", n / dyn_s,
         f"mean_rho={np.mean([r['width'] for r in dyn_out]):.0f}"),
        ("serving/continuous_fixed_qps", n / fix_s, f"rho={cap}"),
        ("serving/continuous_window_ratio", ratio,
         f"{dyn_windows}/{fix_windows} chunk windows"),
        ("serving/continuous_dispatch_ratio",
         dyn_st["n_chunk_calls"] / fix_st["n_chunk_calls"],
         f"{dyn_st['n_chunk_calls']}/{fix_st['n_chunk_calls']} dispatches"),
        ("serving/continuous_wall_ratio", dyn_s / fix_s,
         "PASS" if dyn_s < fix_s else "FAIL"),
        ("serving/continuous_bit_identical", float(bit_identical),
         "PASS" if bit_identical else "FAIL"),
        ("serving/continuous_churn_compiles", churn_compiles,
         "PASS" if churn_compiles == 0 else "FAIL"),
    ]


def bench_three_knob_depth() -> list[tuple]:
    """The three-knob race: per-query depth riding the continuous
    scheduler on each primary knob (rho and k).

    The dynamic arm predicts both the primary parameter and the
    reranking depth per query (content-hash stubs — see
    ``_build_knob_server``); the fixed arm serves everyone at the
    primary's reference with the depth knob off.  Committed fields: the
    stage-2 row fraction the depth mask actually scores (the knob's
    deterministic win — the scheduler counts rows at retirement), the
    per-knob retirement histograms, and the MED acceptance of the
    dynamic arm against its own full-fidelity reference."""
    import jax.numpy as jnp

    from repro.core import med as med_lib
    from repro.online.shadow import reference_param

    rec: dict = {"three_knob_grids": {},
                 "stage2_rows_scored_fraction": {},
                 "knob_retirement_counts": {},
                 "three_knob_window_ratio": {},
                 "dynamic_mean_med": {},
                 "dynamic_inside_med_envelope": {},
                 "three_knob_bit_identical": True}
    rows: list[tuple] = []
    for primary in ("rho", "k"):
        sys_, server = _build_knob_server(primary, with_depth=True)
        n = min(96, sys_.queries.n_queries)
        qt = sys_.queries.terms[:n]
        ref_p = reference_param(server.cfg)

        dyn_b, dyn_out, dyn_s = _continuous_run(server, qt)
        _, fix_server = _build_knob_server(primary)   # depth knob off
        fix_b, fix_out, fix_s = _continuous_run(fix_server, qt,
                                                fixed_param=ref_p)

        # bit-identity of the dynamic arm vs one batch-once serve at
        # the same (primary, depth) vectors
        classes = np.asarray(server.predict_classes(qt))
        dcls, depths = server.predict_depths(qt)
        ranked_ref, _ = server.engine.serve(
            qt, server.params_of(classes), depth_vec=depths)
        bit_identical = all(
            np.array_equal(res["ranked"], ranked_ref[i])
            for i, res in enumerate(dyn_out))
        rec["three_knob_bit_identical"] &= bool(bit_identical)

        # MED of the dynamic run against the full-fidelity reference
        # (primary at its reference, depth unmasked) — the acceptance
        # margin is generous on purpose: hash-stub classes are a *floor*
        # for a trained cascade, and the boolean must not flip on float
        # eps across platforms
        ref = fix_server.serve_fixed(qt, ref_p)["ranked"]
        dyn = np.stack([np.asarray(r["ranked"]) for r in dyn_out])
        med = np.asarray(med_lib.med_rbp(jnp.asarray(dyn),
                                         jnp.asarray(ref), p=0.95))
        mean_med = float(med.mean())

        sch = dyn_b.scheduler.stats()
        frac = sch["n_rows_scored"] / sch["n_rows_full"]
        win_ratio = (sum(r["chunks_executed"] for r in dyn_out)
                     / sum(r["chunks_executed"] for r in fix_out))
        grid = server.cfg.depth_cutoffs
        rec["three_knob_grids"][primary] = [int(c) for c in
                                            server.cfg.cutoffs]
        rec["three_knob_grids"][f"depth@{primary}"] = [int(d)
                                                       for d in grid]
        rec["stage2_rows_scored_fraction"][primary] = round(frac, 4)
        prim_hist = {str(int(r["width"])): 0 for r in dyn_out}
        depth_hist = {str(int(r["depth"])): 0 for r in dyn_out}
        for r in dyn_out:
            prim_hist[str(int(r["width"]))] += 1
            depth_hist[str(int(r["depth"]))] += 1
        rec["knob_retirement_counts"][primary] = prim_hist
        rec["knob_retirement_counts"][f"depth@{primary}"] = depth_hist
        rec["three_knob_window_ratio"][primary] = round(win_ratio, 4)
        rec["dynamic_mean_med"][primary] = round(mean_med, 3)
        rec["dynamic_inside_med_envelope"][primary] = bool(
            mean_med <= 0.35)
        rows += [
            (f"serving/three_knob_{primary}_rows_fraction", frac,
             f"{sch['n_rows_scored']}/{sch['n_rows_full']} stage-2 rows"
             + (" PASS" if frac < 1.0 else " FAIL")),
            (f"serving/three_knob_{primary}_window_ratio", win_ratio,
             "dynamic/fixed chunk windows"),
            (f"serving/three_knob_{primary}_mean_med", mean_med,
             "PASS" if mean_med <= 0.35 else "FAIL"),
            (f"serving/three_knob_{primary}_qps", n / dyn_s,
             f"mean_depth={np.mean([r['depth'] for r in dyn_out]):.0f}"),
        ]
    _RECORDS["knobs"] = rec
    return rows


def bench_paced_deadlines() -> list[tuple]:
    """Paced open-loop arrivals against the continuous scheduler.

    The batch-once admission bench feeds a thundering herd; this one
    paces arrivals (open loop — the submitter never waits on results),
    which is the regime continuous batching exists for: requests join
    in-flight work at the next stage boundary instead of waiting for a
    batch to form, so a generous per-request deadline is met ~always."""
    from repro.serving.service import ContinuousBackend, RetrievalService

    sys_, server = _build_rho_server()
    n = min(96, sys_.queries.n_queries)
    qt = sys_.queries.terms[:n]
    deadline_ms, interval_s = 500.0, 0.002
    backend = ContinuousBackend(server, query_len=qt.shape[1],
                                slots=16, grain=8)
    svc = RetrievalService(backend)
    backend.scheduler.warmup()
    with svc:
        svc.serve_all(list(qt[:16]), deadline_ms=1e9)   # steady state
        t0 = time.perf_counter()
        futs = []
        for row in qt:
            futs.append(svc.submit(row, deadline_ms=deadline_ms))
            time.sleep(interval_s)
        results = [f.result(timeout=60) for f in futs]
        wall_s = time.perf_counter() - t0
    lat = [r["total_ms"] for r in results]
    met = float(np.mean([r["deadline_met"] for r in results]))
    _RECORDS["deadline"] = {
        "paced_n_queries": int(n),
        "paced_interval_ms": interval_s * 1e3,
        "paced_deadline_ms": deadline_ms,
        "deadline_met": met,
    }
    return [
        ("serving/paced_request_p50_ms", float(np.percentile(lat, 50)),
         f"open-loop {interval_s * 1e3:.0f}ms pacing"),
        ("serving/paced_request_p99_ms", float(np.percentile(lat, 99)),
         f"deadline_met={met:.0%}"),
        ("serving/paced_throughput_qps", n / wall_s,
         f"deadline={deadline_ms:.0f}ms"),
    ]


_SHARDED_SCRIPT = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%(n_dev)d")
    sys.path.insert(0, %(src)r)
    import numpy as np
    from repro.core import experiment as E
    from repro.distrib.sharding import make_compat_mesh
    from repro.serving import pipeline as sp

    sys_ = E.build_system(E.ExperimentConfig(
        n_docs=%(n_docs)d, vocab=%(n_docs)d * 2, n_queries=256,
        stream_cap=%(cap)d, pool_depth=1000, gold_depth=200,
        query_batch=128))

    def make_server(mesh=None):
        # slack 2.5: the smoke corpus's doc skew puts up to ~0.56*cap of
        # a query's postings on one shard (measured; 2.0 overflows)
        cfg = sp.ServingConfig(knob="k", cutoffs=sys_.k_cutoffs,
                               rerank_depth=100,
                               stream_cap=sys_.cfg.stream_cap,
                               partition_slack=2.5)
        srv = sp.RetrievalServer(sys_.index, None, cfg, mesh=mesh)
        srv.predict_classes = (
            lambda qt: np.arange(qt.shape[0]) %% (len(sys_.k_cutoffs) + 1))
        return srv

    def best_qps(server, qt, n=3):
        server.serve_batch(qt)            # warm
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            server.serve_batch(qt)
            ts.append(time.perf_counter() - t0)
        return qt.shape[0] / min(ts)

    qt = sys_.queries.terms[:128]
    single = make_server()
    sharded = make_server(make_compat_mesh((1, %(n_shards)d),
                                           ("data", "model")))
    a = single.serve_batch(qt)["ranked"]
    b = sharded.serve_batch(qt)["ranked"]
    eng = sharded.engine
    print(json.dumps({
        "single_qps": best_qps(single, qt),
        "sharded_qps": best_qps(sharded, qt),
        "n_shards": %(n_shards)d,
        "bit_identical": bool(np.array_equal(a, b)),
        "stream_cap": int(eng.cfg.stream_cap),
        "shard_stream_cap": int(eng.shard_cap),
        "partition_slack": float(eng.cfg.partition_slack),
    }))
""")


def bench_sharded_vs_single() -> list[tuple]:
    """Mesh-sharded engine vs single device, on a forced-host-device mesh.

    Runs in a subprocess (XLA's forced device count must be set before
    backend init).  On emulated CPU devices the sharded path pays real
    collective overhead for no real parallel FLOPs — the number tracks
    that overhead across PRs; on TPU the same code path is the scaling
    story.  Also asserts the sharded output is bit-identical.
    """
    n_shards = int(os.environ.get("REPRO_BENCH_SHARDS", "4"))
    smoke = os.environ.get("REPRO_BENCH_SCALE") == "tiny"
    script = _SHARDED_SCRIPT % dict(
        n_dev=n_shards,
        src=os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src"),
        n_docs=2000 if smoke else 8000,
        cap=512 if smoke else 2048,
        n_shards=n_shards,
    )
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(f"sharded bench subprocess failed:\n{r.stderr}")
    out = json.loads(r.stdout.strip().splitlines()[-1])
    if not out["bit_identical"]:
        raise RuntimeError("sharded engine diverged from single-device")
    ratio = out["sharded_qps"] / out["single_qps"]
    # deterministic partition-volume counters: per-shard stream length is
    # a pure function of (stream_cap, n_shards, partition_slack), so the
    # ~1/n_shards gather/scan-volume claim is committed and diff-checked
    cap, scap = out["stream_cap"], out["shard_stream_cap"]
    frac = scap / cap
    _RECORDS["sharded"] = {
        "sharded_n_shards": int(out["n_shards"]),
        "sharded_stream_cap": int(cap),
        "sharded_shard_stream_cap": int(scap),
        "sharded_stream_fraction": round(frac, 4),
        "sharded_partition_slack": out["partition_slack"],
        # the per-shard stream carries <= slack/n_shards of the global
        # postings (modulo the 8-wide alignment of partition_cap)
        "sharded_volume_scales": bool(
            scap <= out["partition_slack"] * cap / out["n_shards"] + 8),
        "sharded_bit_identical": bool(out["bit_identical"]),
        "sharded_vs_single_throughput": round(ratio, 4),
    }
    return [
        ("serving/single_device_qps", out["single_qps"], "128q batch"),
        (f"serving/sharded_{n_shards}dev_qps", out["sharded_qps"],
         "forced host devices, candidates over 'model'"),
        ("serving/sharded_vs_single_throughput", ratio,
         f"bit_identical={out['bit_identical']} "
         f"shard_stream={scap}/{cap}"),
    ]


def bench_obs_overhead() -> list[tuple]:
    """The observability tax, and the committed bound on it.

    Runs the same continuous churn stream twice — recorder off
    (``NULL_OBS``) vs on — and reports the wall-clock ratio.  The
    committed record carries ``obs_overhead_bounded`` (best-of-3 ratio
    under a generous machine-independent margin) plus the deterministic
    ``obs_counters`` block from one clean instrumented run: submissions,
    working ticks and retirements are pure functions of (code, stream),
    so the counter surface is diff-checked like the dispatch counts.
    Also asserts the instrumentation itself compiles nothing (spans wrap
    dispatch boundaries, never traced code) and that every span closed.
    """
    from repro.obs import NULL_OBS, Observability
    from repro.serving.service import ContinuousBackend, RetrievalService

    sys_, server = _build_rho_server()
    n = min(96, sys_.queries.n_queries)
    qt = sys_.queries.terms[:n]

    def run(obs):
        backend = ContinuousBackend(server, query_len=qt.shape[1],
                                    slots=8, grain=8)
        svc = RetrievalService(backend, obs=obs)
        backend.scheduler.warmup()        # compile off the timed path
        svc.serve_all(list(qt), deadline_ms=1e9)   # warm pass
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            svc.serve_all(list(qt), deadline_ms=1e9)
            ts.append(time.perf_counter() - t0)
        return min(ts)

    off_s = run(NULL_OBS)
    obs = Observability.create(capacity=1 << 15)
    n0 = server.engine.n_compiles
    on_s = run(obs)
    obs_compiles = server.engine.n_compiles - n0
    ratio = on_s / off_s
    bounded = ratio <= 1.5                # generous: the real tax is ~1%

    # one fresh instrumented run for the deterministic counter surface
    # (serve_all ticks inline here — no service threads — so even the
    # working-tick count is a pure function of the stream)
    obs1 = Observability.create(capacity=1 << 15)
    backend = ContinuousBackend(server, query_len=qt.shape[1],
                                slots=8, grain=8)
    svc = RetrievalService(backend, obs=obs1)
    backend.scheduler.warmup()
    svc.serve_all(list(qt), deadline_ms=1e9)
    tc = obs1.trace.counts()
    c = obs1.metrics.counters()
    _RECORDS["obs"] = {
        "obs_overhead_bounded": bool(bounded),
        "obs_zero_new_compiles": bool(obs_compiles == 0),
        "obs_spans_balanced": bool(
            tc["n_open"] == 0 and tc["n_begun"] == tc["n_ended"]),
        "obs_counters": {k: int(c[k]) for k in (
            "queue.submitted", "sched.ticks",
            "sched.retired.rho_exhausted",
            "sched.retired.stream_exhausted",
            "sched.retired.pool_complete")},
    }
    return [
        ("serving/obs_off_96q_us", off_s / n * 1e6, "NULL_OBS"),
        ("serving/obs_on_96q_us", on_s / n * 1e6,
         f"{tc['n_begun']}_spans_per_pass"),
        ("serving/obs_overhead_ratio", ratio,
         "PASS" if bounded else "FAIL"),
        ("serving/obs_new_compiles", obs_compiles,
         "PASS" if obs_compiles == 0 else "FAIL"),
    ]


# ----------------------------------------------------------- JSON output --

def payload_from_rows(rows: list[tuple]) -> dict:
    """Distill the serving rows into the cross-PR trajectory record."""
    by_name = {name: (val, derived) for name, val, derived in rows}

    def val(name):
        return float(by_name[name][0]) if name in by_name else None

    stage_ms = {
        name.removeprefix("serving/stage_").removesuffix("_us"):
            float(v) / 1e3
        for name, (v, _) in by_name.items()
        if name.startswith("serving/stage_")}
    ratio = val("serving/dynamic_vs_fixed_ratio")
    n_compiles = val("serving/executable_cache")
    has_sharded = any(name.startswith("serving/sharded_")
                      or name == "serving/single_device_qps"
                      for name in by_name)
    return {
        "sharded_vs_single_device": {
            "single_qps": val("serving/single_device_qps"),
            "sharded_qps": next(
                (float(v) for name, (v, _) in by_name.items()
                 if name.startswith("serving/sharded_")
                 and name.endswith("dev_qps")), None),
            "throughput_ratio": val(
                "serving/sharded_vs_single_throughput"),
        } if has_sharded else None,
        "p50_ms": val("serving/admission_request_p50_ms"),
        "p99_ms": val("serving/admission_request_p99_ms"),
        "queue_p50_ms": val("serving/admission_queue_p50_ms"),
        "service_p50_ms": val("serving/admission_service_p50_ms"),
        "throughput_qps": val("serving/admission_throughput_qps"),
        "stage_ms": stage_ms,
        "n_compiles": None if n_compiles is None else int(n_compiles),
        "dynamic_vs_fixed_ratio": ratio,
        "dynamic_vs_fixed_speedup": None if not ratio else 1.0 / ratio,
        "rows": [[name, float(v), str(d)] for name, v, d in rows],
    }


def summary_payload() -> dict | None:
    """The committed record: deterministic counts/booleans only.

    Assembled from the continuous-scheduler race, the paced deadline
    bench and the sharded-vs-single race; every field is a pure function
    of (code, seed) — no wall clock — except the acceptance booleans
    (committed with enough margin to be machine-independent in outcome)
    and the measured sharded_vs_single_throughput, which the bench-smoke
    diff explicitly excludes."""
    if _RECORDS["scheduler"] is None:
        return None
    payload = dict(_RECORDS["scheduler"])
    payload.update(_RECORDS["deadline"] or {})
    # every sharded field is deterministic except the measured
    # sharded_vs_single_throughput, which bench-smoke excludes from the
    # exact diff (git diff -I) so the committed trajectory can move
    payload.update(_RECORDS["sharded"] or {})
    payload.update(_RECORDS["knobs"] or {})
    payload.update(_RECORDS["obs"] or {})
    return payload


def write_bench_json(rows: list[tuple], path: str | None = None) -> str:
    """Committed summary + gitignored full record (same contract as
    BENCH_online.json: the summary is defined at the CI smoke scale, so
    a default-scale run never dirties the diff-checked file)."""
    from benchmarks import common
    explicit = path is not None or "REPRO_BENCH_JSON" in os.environ
    path = path or os.environ.get("REPRO_BENCH_JSON", BENCH_JSON)
    os.makedirs(ART, exist_ok=True)
    wrote = None
    summary = summary_payload()
    if summary is not None and (explicit or common.scale_name() == "tiny"):
        summary["scale"] = common.scale_name()
        with open(path, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True)
            f.write("\n")
        wrote = path
    full = payload_from_rows(rows)
    full["summary"] = summary
    # the obs record rides along even when --only skipped the rest of
    # the suite: CI's obs-smoke diff-checks these fields against the
    # committed summary without paying for the full bench run
    full["obs"] = _RECORDS["obs"]
    full["scale"] = common.scale_name()
    full["unix_time"] = time.time()
    with open(FULL_JSON, "w") as f:
        json.dump(full, f, indent=2, sort_keys=True)
    return os.path.abspath(wrote or FULL_JSON)


BENCHES = [bench_dynamic_vs_fixed, bench_compile_amortization,
           bench_admission_service, bench_continuous_scheduler,
           bench_three_knob_depth, bench_paced_deadlines,
           bench_sharded_vs_single, bench_obs_overhead]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale, interpret mode (CI)")
    ap.add_argument("--only", default=None,
                    help="run only benches whose name contains this "
                         "substring (the committed summary needs the "
                         "full set — use for iteration, not artifacts)")
    ap.add_argument("--out", default=None,
                    help=f"JSON output path (default {BENCH_JSON})")
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ["REPRO_BENCH_SCALE"] = "tiny"

    benches = [b for b in BENCHES
               if args.only is None or args.only in b.__name__]
    print("name,us_per_call,derived")
    rows: list[tuple] = []
    for b in benches:
        for row in b():
            rows.append(row)
            name, v, derived = row
            print(f"{name},{v:.1f},{derived}", flush=True)
    path = write_bench_json(rows, args.out)
    print(f"wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main()
