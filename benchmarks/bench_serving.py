"""Serving benchmarks: dynamic vs fixed wall-clock under single dispatch.

The honest comparison the paper's efficiency claim needs: the dynamic
path (cascade prediction + traced per-query parameter) must not cost more
wall-clock than serving everyone at the fixed maximum parameter.  With
the single-dispatch engine both paths share the same executables, so the
dynamic overhead is exactly the cascade forward pass — reported here as
per-stage timings plus the executable-cache size (compile count).
"""

from __future__ import annotations

import time

import numpy as np


def _build_server():
    from benchmarks import common
    from repro.core import cascade as cl
    from repro.core import labeling
    from repro.serving import pipeline as sp

    sys_ = common.get_system()
    m = common.get_med("k")["rbp"]
    labels = np.asarray(labeling.envelope_labels(m, 0.05))
    casc = cl.train_cascade(sys_.features, labels,
                            n_cutoffs=len(sys_.k_cutoffs),
                            forest_kwargs=common.forest_kwargs())
    cfg = sp.ServingConfig(knob="k", cutoffs=sys_.k_cutoffs,
                           threshold=0.75, rerank_depth=100,
                           stream_cap=sys_.cfg.stream_cap)
    return sys_, sp.RetrievalServer(sys_.index, casc, cfg)


def bench_dynamic_vs_fixed() -> list[tuple]:
    """Acceptance row: dynamic wall-clock at or below fixed max-param."""
    sys_, server = _build_server()
    qt = sys_.queries.terms[:256]
    qlen = qt.shape[1]
    server.engine.warmup([256], qlen)     # compile off the timed path

    def best_of(fn, n=3):
        ts = []
        for _ in range(n):
            t0 = time.time()
            fn()
            ts.append(time.time() - t0)
        return min(ts)

    server.serve_batch(qt)                # cascade jit warmup
    dyn_s = best_of(lambda: server.serve_batch(qt))
    fix_s = best_of(lambda: server.serve_fixed(qt, sys_.k_cutoffs[-1]))
    out = server.serve_batch(qt)
    rows = [
        ("serving/dynamic_single_dispatch_256q", dyn_s / 256 * 1e6,
         f"mean_k={out['mean_param']:.0f}"),
        ("serving/fixed_max_single_dispatch_256q", fix_s / 256 * 1e6,
         f"mean_k={sys_.k_cutoffs[-1]}"),
        ("serving/dynamic_vs_fixed_ratio", dyn_s / fix_s,
         "PASS" if dyn_s <= fix_s * 1.05 else "FAIL"),
        ("serving/executable_cache", server.engine.n_compiles,
         "compiles (constant in class diversity)"),
    ]
    for key, ms in out["timings"].items():
        stage = key.removesuffix("_ms")
        rows.append((f"serving/stage_{stage}_us", ms * 1e3,
                     "per 256q batch"))
    return rows


def bench_compile_amortization() -> list[tuple]:
    """Per-bucket reference vs single dispatch on a many-bucket batch."""
    sys_, server = _build_server()
    qt = sys_.queries.terms[:128]
    server.serve_batch(qt)                # warm both paths
    server.serve_batch_reference(qt)
    t0 = time.time()
    server.serve_batch(qt)
    dyn_s = time.time() - t0
    t0 = time.time()
    out_ref = server.serve_batch_reference(qt)
    ref_s = time.time() - t0
    n_buckets = len(set(out_ref["classes"].tolist()))
    return [
        ("serving/single_dispatch_128q", dyn_s / 128 * 1e6,
         f"{n_buckets}_live_buckets"),
        ("serving/per_bucket_reference_128q", ref_s / 128 * 1e6,
         f"{n_buckets}_live_buckets"),
    ]
