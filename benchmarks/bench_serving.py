"""Serving benchmarks: dynamic vs fixed wall-clock under single dispatch,
plus the async admission path (RetrievalService) end to end.

The honest comparison the paper's efficiency claim needs: the dynamic
path (cascade prediction + traced per-query parameter) must not cost more
wall-clock than serving everyone at the fixed maximum parameter.  With
the single-dispatch engine both paths share the same executables, so the
dynamic overhead is exactly the cascade forward pass — reported here as
per-stage timings plus the executable-cache size (compile count).

Machine-readable output: every run (``python benchmarks/bench_serving.py``
or via ``benchmarks/run.py``) writes ``artifacts/BENCH_serving.json``
with p50/p99, the queue-delay vs service-time breakdown, per-stage ms,
compile count, and the dynamic-vs-fixed speedup, so the perf trajectory
is tracked across PRs.  ``--smoke`` runs the tiny scale for CI.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                          "BENCH_serving.json")


def _build_server():
    from benchmarks import common
    from repro.core import cascade as cl
    from repro.core import labeling
    from repro.serving import pipeline as sp

    sys_ = common.get_system()
    m = common.get_med("k")["rbp"]
    labels = np.asarray(labeling.envelope_labels(m, 0.05))
    casc = cl.train_cascade(sys_.features, labels,
                            n_cutoffs=len(sys_.k_cutoffs),
                            forest_kwargs=common.forest_kwargs())
    cfg = sp.ServingConfig(knob="k", cutoffs=sys_.k_cutoffs,
                           threshold=0.75, rerank_depth=100,
                           stream_cap=sys_.cfg.stream_cap)
    return sys_, sp.RetrievalServer(sys_.index, casc, cfg)


def bench_dynamic_vs_fixed() -> list[tuple]:
    """Acceptance row: dynamic wall-clock at or below fixed max-param."""
    sys_, server = _build_server()
    qt = sys_.queries.terms[:256]
    qlen = qt.shape[1]
    server.engine.warmup([256], qlen)     # compile off the timed path

    def best_of(fn, n=3):
        ts = []
        for _ in range(n):
            t0 = time.time()
            fn()
            ts.append(time.time() - t0)
        return min(ts)

    server.serve_batch(qt)                # cascade jit warmup
    dyn_s = best_of(lambda: server.serve_batch(qt))
    fix_s = best_of(lambda: server.serve_fixed(qt, sys_.k_cutoffs[-1]))
    out = server.serve_batch(qt)
    rows = [
        ("serving/dynamic_single_dispatch_256q", dyn_s / 256 * 1e6,
         f"mean_k={out['mean_param']:.0f}"),
        ("serving/fixed_max_single_dispatch_256q", fix_s / 256 * 1e6,
         f"mean_k={sys_.k_cutoffs[-1]}"),
        ("serving/dynamic_vs_fixed_ratio", dyn_s / fix_s,
         "PASS" if dyn_s <= fix_s * 1.05 else "FAIL"),
        ("serving/executable_cache", server.engine.n_compiles,
         "compiles (constant in class diversity)"),
    ]
    for key, ms in out["timings"].items():
        stage = key.removesuffix("_ms")
        rows.append((f"serving/stage_{stage}_us", ms * 1e3,
                     "per 256q batch"))
    return rows


def bench_compile_amortization() -> list[tuple]:
    """Per-bucket reference vs single dispatch on a many-bucket batch."""
    sys_, server = _build_server()
    qt = sys_.queries.terms[:128]
    server.serve_batch(qt)                # warm both paths
    server.serve_batch_reference(qt)
    t0 = time.time()
    server.serve_batch(qt)
    dyn_s = time.time() - t0
    t0 = time.time()
    out_ref = server.serve_batch_reference(qt)
    ref_s = time.time() - t0
    n_buckets = len(set(out_ref["classes"].tolist()))
    return [
        ("serving/single_dispatch_128q", dyn_s / 128 * 1e6,
         f"{n_buckets}_live_buckets"),
        ("serving/per_bucket_reference_128q", ref_s / 128 * 1e6,
         f"{n_buckets}_live_buckets"),
    ]


def bench_admission_service() -> list[tuple]:
    """The unified async path: deadline-driven admission end to end.

    Feeds a query stream through RetrievalService (threaded: prediction
    for batch N+1 overlapping dispatch of batch N) and reports request
    latency percentiles with the queue-vs-service breakdown the
    deployment loop tunes deadlines against.
    """
    from repro.serving.admission import AdmissionConfig
    from repro.serving.service import EngineBackend, RetrievalService

    sys_, server = _build_server()
    n_stream = min(512, sys_.queries.n_queries)
    qt = sys_.queries.terms[:n_stream]
    backend = EngineBackend(server, query_len=qt.shape[1])
    service = RetrievalService(backend, AdmissionConfig(
        max_batch=128, pad_multiple=server.cfg.pad_multiple,
        max_wait_ms=2.0, default_deadline_ms=100.0))
    service.warmup_now([128])             # deploy-time shape
    with service:
        service.serve_all(list(qt[:128]))     # cascade jit warmup
        t0 = time.time()
        results = service.serve_all(list(qt))
        wall_s = time.time() - t0
    # total_ms spans submit -> resolve (incl. the predict/execute handoff
    # wait), the same clock deadline_met is judged against
    lat = [r["total_ms"] for r in results]
    met = np.mean([r["deadline_met"] for r in results])
    return [
        ("serving/admission_request_p50_ms", float(np.percentile(lat, 50)),
         f"{n_stream}q_stream"),
        ("serving/admission_request_p99_ms", float(np.percentile(lat, 99)),
         f"deadline_met={met:.0%}"),
        ("serving/admission_queue_p50_ms",
         float(np.percentile([r["queue_ms"] for r in results], 50)),
         "admission delay"),
        ("serving/admission_service_p50_ms",
         float(np.percentile([r["service_ms"] for r in results], 50)),
         "backend execute"),
        ("serving/admission_throughput_qps", n_stream / wall_s,
         f"shapes={sorted(service.queue.shape_counts)}"),
        ("serving/admission_warmed_shapes", len(service.warmup.compiled),
         "learned warmup policy"),
    ]


_SHARDED_SCRIPT = textwrap.dedent("""
    import os, sys, json, time
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%(n_dev)d")
    sys.path.insert(0, %(src)r)
    import numpy as np
    from repro.core import experiment as E
    from repro.distrib.sharding import make_compat_mesh
    from repro.serving import pipeline as sp

    sys_ = E.build_system(E.ExperimentConfig(
        n_docs=%(n_docs)d, vocab=%(n_docs)d * 2, n_queries=256,
        stream_cap=%(cap)d, pool_depth=1000, gold_depth=200,
        query_batch=128))

    def make_server(mesh=None):
        cfg = sp.ServingConfig(knob="k", cutoffs=sys_.k_cutoffs,
                               rerank_depth=100,
                               stream_cap=sys_.cfg.stream_cap)
        srv = sp.RetrievalServer(sys_.index, None, cfg, mesh=mesh)
        srv.predict_classes = (
            lambda qt: np.arange(qt.shape[0]) %% (len(sys_.k_cutoffs) + 1))
        return srv

    def best_qps(server, qt, n=3):
        server.serve_batch(qt)            # warm
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            server.serve_batch(qt)
            ts.append(time.perf_counter() - t0)
        return qt.shape[0] / min(ts)

    qt = sys_.queries.terms[:128]
    single = make_server()
    sharded = make_server(make_compat_mesh((1, %(n_shards)d),
                                           ("data", "model")))
    a = single.serve_batch(qt)["ranked"]
    b = sharded.serve_batch(qt)["ranked"]
    print(json.dumps({
        "single_qps": best_qps(single, qt),
        "sharded_qps": best_qps(sharded, qt),
        "n_shards": %(n_shards)d,
        "bit_identical": bool(np.array_equal(a, b)),
    }))
""")


def bench_sharded_vs_single() -> list[tuple]:
    """Mesh-sharded engine vs single device, on a forced-host-device mesh.

    Runs in a subprocess (XLA's forced device count must be set before
    backend init).  On emulated CPU devices the sharded path pays real
    collective overhead for no real parallel FLOPs — the number tracks
    that overhead across PRs; on TPU the same code path is the scaling
    story.  Also asserts the sharded output is bit-identical.
    """
    n_shards = int(os.environ.get("REPRO_BENCH_SHARDS", "4"))
    smoke = os.environ.get("REPRO_BENCH_SCALE") == "tiny"
    script = _SHARDED_SCRIPT % dict(
        n_dev=n_shards,
        src=os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src"),
        n_docs=2000 if smoke else 8000,
        cap=512 if smoke else 2048,
        n_shards=n_shards,
    )
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(f"sharded bench subprocess failed:\n{r.stderr}")
    out = json.loads(r.stdout.strip().splitlines()[-1])
    if not out["bit_identical"]:
        raise RuntimeError("sharded engine diverged from single-device")
    ratio = out["sharded_qps"] / out["single_qps"]
    return [
        ("serving/single_device_qps", out["single_qps"], "128q batch"),
        (f"serving/sharded_{n_shards}dev_qps", out["sharded_qps"],
         "forced host devices, candidates over 'model'"),
        ("serving/sharded_vs_single_throughput", ratio,
         f"bit_identical={out['bit_identical']}"),
    ]


# ----------------------------------------------------------- JSON output --

def payload_from_rows(rows: list[tuple]) -> dict:
    """Distill the serving rows into the cross-PR trajectory record."""
    by_name = {name: (val, derived) for name, val, derived in rows}

    def val(name):
        return float(by_name[name][0]) if name in by_name else None

    stage_ms = {
        name.removeprefix("serving/stage_").removesuffix("_us"):
            float(v) / 1e3
        for name, (v, _) in by_name.items()
        if name.startswith("serving/stage_")}
    ratio = val("serving/dynamic_vs_fixed_ratio")
    n_compiles = val("serving/executable_cache")
    has_sharded = any(name.startswith("serving/sharded_")
                      or name == "serving/single_device_qps"
                      for name in by_name)
    return {
        "sharded_vs_single_device": {
            "single_qps": val("serving/single_device_qps"),
            "sharded_qps": next(
                (float(v) for name, (v, _) in by_name.items()
                 if name.startswith("serving/sharded_")
                 and name.endswith("dev_qps")), None),
            "throughput_ratio": val(
                "serving/sharded_vs_single_throughput"),
        } if has_sharded else None,
        "p50_ms": val("serving/admission_request_p50_ms"),
        "p99_ms": val("serving/admission_request_p99_ms"),
        "queue_p50_ms": val("serving/admission_queue_p50_ms"),
        "service_p50_ms": val("serving/admission_service_p50_ms"),
        "throughput_qps": val("serving/admission_throughput_qps"),
        "stage_ms": stage_ms,
        "n_compiles": None if n_compiles is None else int(n_compiles),
        "dynamic_vs_fixed_ratio": ratio,
        "dynamic_vs_fixed_speedup": None if not ratio else 1.0 / ratio,
        "rows": [[name, float(v), str(d)] for name, v, d in rows],
    }


def write_bench_json(rows: list[tuple], path: str | None = None) -> str:
    from benchmarks import common
    path = path or os.environ.get("REPRO_BENCH_JSON", BENCH_JSON)
    payload = payload_from_rows(rows)
    payload["scale"] = common.scale_name()
    payload["unix_time"] = time.time()
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return os.path.abspath(path)


BENCHES = [bench_dynamic_vs_fixed, bench_compile_amortization,
           bench_admission_service, bench_sharded_vs_single]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny scale, interpret mode (CI)")
    ap.add_argument("--out", default=None,
                    help=f"JSON output path (default {BENCH_JSON})")
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ["REPRO_BENCH_SCALE"] = "tiny"

    print("name,us_per_call,derived")
    rows: list[tuple] = []
    for b in BENCHES:
        for row in b():
            rows.append(row)
            name, v, derived = row
            print(f"{name},{v:.1f},{derived}", flush=True)
    path = write_bench_json(rows, args.out)
    print(f"wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main()
